"""Pallas TPU kernels for the paper's compute hot-spot: sparse conv/matmul.

- `vsmm`   -- vector-sparse matmul (scalar-prefetch block-CSR, the paper's
             index system as BlockSpec.index_map, runtime input-vector skip,
             optional fused bias+ReLU epilogue)
- `vsconv` -- direct KxK/stride vector-sparse convolution family
             (tap-granular weight skip; 1x1 routes through vsmm over
             pixels; fused bias+ReLU epilogue; impl="halo" reads the raw
             SAME-padded input through overlapping halo blocks — ~1x-input
             HBM traffic — impl="stack" keeps the materialized row-tap
             stack as oracle/fallback)
- `flash`  -- flash-attention forward (VMEM-resident online softmax; the
             dominant HBM term of every train/prefill roofline cell)
- `ref`    -- pure-jnp oracles
- `ops`    -- jit'd public wrappers (padding, backend dispatch)

Validated with interpret=True on CPU; compiled paths target TPU v5e.
"""
from .ops import vsmm, vsconv
from .flash import flash_fwd_pallas
from . import ref
