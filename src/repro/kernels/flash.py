"""Pallas TPU flash-attention (forward) kernel.

The roofline audit (EXPERIMENTS §Perf, cell C) shows the dominant HBM term
of every training/prefill cell is the online-softmax score chain — XLA:CPU
materializes the (bq, bk) f32 score block ~10x per KV step.  This kernel is
the TPU answer: the whole chain (scores -> mask -> running max -> exp ->
accumulate) lives in VMEM; HBM traffic is exactly q/k/v reads + one output
write.  Used by the serving path (prefill has no backward); training uses
the jnp flash (attention.flash_attention) whose backward XLA derives.

Layout: q (BH, Tq, hd), k/v (BH, Tk, hd) — heads flattened into the leading
grid dim so one kernel covers MHA/GQA (repeat KV before the call, as the
jnp path does).  Grid (BH, nq, nk), kv innermost; the output tile is
revisited and normalized at the last kv step.  Causal/window masking is
positional; fully-masked kv blocks issue no MXU op (@pl.when — the same
skip the paper applies to zero vectors, here to masked blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_fwd_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, causal: bool, window, q_offset: int,
            scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos0 = q_offset + qi * bq
    kpos0 = ki * bk
    # block-level skip: no query in this tile attends to this kv tile
    live = True
    if causal:
        live = qpos0 + bq - 1 >= kpos0
    if window is not None:
        live = jnp.logical_and(live, qpos0 < kpos0 + bk + window - 1) \
            if causal else (qpos0 < kpos0 + bk + window - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                  # (bq, bk)
        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ki == pl.num_programs(2) - 1)
    def _flush():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_fwd_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q (BH, Tq, hd), k/v (BH, Tk, hd) -> (BH, Tq, hd).

    Tq % bq == 0 and Tk % bk == 0 (callers pad); hd should be a multiple of
    128 on real TPUs (any value works in interpret mode).
    """
    bh, tq, hd = q.shape
    _, tk, _ = k.shape
    assert tq % bq == 0 and tk % bk == 0, (tq, bq, tk, bk)
    nq, nk = tq // bq, tk // bk
    scale = hd ** -0.5

    grid = (bh, nq, nk)
    return pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bk, causal=causal, window=window,
            q_offset=q_offset, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=int(4 * bh * tq * tk * hd * (0.5 if causal else 1.0)),
            bytes_accessed=int(
                q.size * q.dtype.itemsize
                + nq * (k.size + v.size) * k.dtype.itemsize
                + q.size * q.dtype.itemsize
            ),
            transcendentals=int(bh * tq * tk),
        ),
    )(q, k, v)
