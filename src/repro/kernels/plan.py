"""Static kernel plans: the Pallas dispatch of `ops.vsconv`/`ops.vsmm`
re-derived from pure geometry, with no arrays and no kernel execution.

A `KernelPlan` is everything the static analyzer (`repro.analysis`) needs
to *prove* a kernel invocation correct ahead of time:

  * the grid and every buffer's `BufferAccess` — block shape, buffer
    dims, the *same* `index_map` callable the kernel hands
    `pl.BlockSpec` (the named factories in `kernels.vsconv` /
    `kernels.vsmm`), and the DMA-counting policy its cost formula
    assumes;
  * the kernel's own `pl.CostEstimate` exactly as the wrapper would
    compute it (same cost functions, same padded extents).

`conv_plan` / `fc_plan` replicate the `ops.vsconv` / `ops.vsmm` wrapper
dispatch — 1x1-via-vsmm routing, depthwise detection, resident-halo
selection, bh/hop/bm padding — from static shapes only, so the analyzer
checks the kernel that would actually run, not an idealization.

DMA-counting policies (how the cost contract counts block fetches):

  ``distinct``        one DMA per globally distinct offset tuple — weight
                      stream, output/residual tiles, the resident and
                      depthwise halo blocks.
  ``sweep_distinct``  distinct offsets within each sweep of the inner
                      grid axes (outer ``sweep_axes`` fixed), summed over
                      sweeps — the streaming halo input, whose
                      min(S, CB) per-(strip, row-block) fetch floor
                      relies on Pallas skipping the DMA when consecutive
                      steps revisit the same block *within* a sweep but
                      not across strips.
  ``per_step``        one DMA per grid step — the row-tap stack input and
                      the vsmm activation gather, whose block index
                      changes (in the model) every sparse step.
  ``excluded``        not part of the byte contract (the (1, vn) bias
                      and int8 dequant-scale tiles: one tile per strip,
                      noise next to the other terms) — bounds are still
                      proven.

The faithful Pallas rule — skip the DMA whenever a step's offsets equal
the *immediately previous* step's — is simulated separately by the
analyzer and asserted ``<=`` the policy count (the contract must be a
sound upper bound; rule VSC204).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

from jax.experimental import pallas as pl

from .vsconv import (
    conv_bias_index_map, conv_out_index_map, conv_weight_index_map,
    dw_halo_in_index_map, dw_halo_kernel_cost, dw_stack_in_index_map,
    dw_stack_kernel_cost, halo_in_index_map, halo_kernel_cost,
    halo_layout_dims, resident_in_index_map, same_pads, stack_in_index_map,
    stack_kernel_cost, stack_layout_dims, use_resident_halo,
)
from .vsmm import (
    vsmm_bias_index_map, vsmm_kernel_cost, vsmm_out_index_map,
    vsmm_w_index_map, vsmm_x_index_map,
)

__all__ = ["BufferAccess", "KernelPlan", "conv_plan", "fc_plan"]

IndexMap = Callable[..., tuple[Any, ...]]

POLICIES = ("distinct", "sweep_distinct", "per_step", "excluded")


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class BufferAccess:
    """One pallas_call operand: its BlockSpec geometry plus the
    DMA-counting policy the cost contract assumes for it.

    ``dims`` is the full (padded) buffer shape; ``valid`` the logically
    meaningful extents per axis (== dims except where a wrapper padded —
    the vsmm row axis), letting the analyzer quote bytes both at the
    kernel's padded extents and at `conv_layer_traffic`'s logical ones.
    ``unblocked`` means the index map yields element offsets
    (`pl.Unblocked`); otherwise block indices scaled by ``block``.
    """

    name: str
    block: tuple[int, ...]
    dims: tuple[int, ...]
    valid: tuple[int, ...]
    index_map: IndexMap
    policy: str
    itemsize: int
    unblocked: bool = False
    sweep_axes: tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"unknown DMA policy {self.policy!r}")
        if len(self.block) != len(self.dims) or len(self.dims) != len(
                self.valid):
            raise ValueError(
                f"{self.name}: rank mismatch {self.block}/{self.dims}")

    @property
    def block_elems(self) -> int:
        n = 1
        for b in self.block:
            n *= b
        return n


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """The statically derived shape of one kernel invocation."""

    kind: str                      # halo|resident|stack|dw_halo|dw_stack|vsmm
    grid: tuple[int, int, int]     # (g0, g1, g2); g2 is the sparse-step axis
    kb: int                        # stored-tile-id bound (idx values < kb)
    nb: int                        # strips (the idx table is (nb, s_steps))
    s_steps: int
    buffers: tuple[BufferAccess, ...]
    cost: pl.CostEstimate          # the kernel's own claimed CostEstimate
    flops_per_step: int            # 2 * MACs issued by one grid step

    def buffer(self, name: str) -> BufferAccess:
        for b in self.buffers:
            if b.name == name:
                return b
        raise KeyError(name)


def conv_plan(
    x_shape: Sequence[int],
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    groups: int = 1,
    dilation: int = 1,
    cout: int,
    s_steps: int,
    vk: int,
    vn: int,
    bh: int = 8,
    impl: str = "halo",
    has_bias: bool = False,
    has_residual: bool = False,
    has_scale: bool = False,
    itemsize: int = 4,
    w_itemsize: int | None = None,
    out_itemsize: int | None = None,
) -> KernelPlan:
    """The `ops.vsconv` dispatch from static geometry.

    ``x_shape`` is the *encoded* NHWC input (Cin a vk multiple, pad
    channels included), ``cout`` the encoded output width (a vn multiple)
    — the same conventions as `core.accel_model.conv_layer_traffic`, whose
    byte totals the resulting plan must reproduce.

    The dtype axis: ``itemsize`` is the activation/input width,
    ``w_itemsize`` the stored weight width (defaults to ``itemsize``;
    1 for the int8 kernels), ``out_itemsize`` the output width (defaults
    to ``itemsize``; the int8 path emits f32, so 4).  The f32 bias, the
    f32 residual and the f32 dequant ``scale`` (``has_scale``) are always
    ``out_itemsize`` wide.
    """
    n, h, w, c = (int(d) for d in x_shape)
    if impl not in ("halo", "stack"):
        raise ValueError(f"impl must be 'halo' or 'stack', got {impl!r}")
    assert c % vk == 0 and cout % vn == 0, (x_shape, cout, vk, vn)
    out_itemsize = out_itemsize or itemsize
    w_itemsize = w_itemsize or itemsize
    nb = cout // vn
    cb = c // vk
    depthwise = groups > 1 and groups == c and vk == 1 and cout == c
    assert c % groups == 0 and (depthwise or cb % groups == 0), (
        x_shape, vk, groups)

    if kh == 1 and kw == 1 and groups == 1:
        ho = -(-h // stride)
        wo = -(-w // stride)
        return fc_plan(
            m=n * ho * wo, k=c, s_steps=s_steps, vk=vk, vn=vn, nb=nb,
            has_bias=has_bias, has_residual=has_residual,
            has_scale=has_scale, itemsize=itemsize, w_itemsize=w_itemsize,
            out_itemsize=out_itemsize,
        )

    ho, _, _ = same_pads(h, kh, stride, dilation)
    wo, _, _ = same_pads(w, kw, stride, dilation)
    bh = min(bh, ho)
    hop = _round_up(ho, bh)
    hb = hop // bh
    hh = stride * (bh - 1) + (kh - 1) * dilation + 1
    res_bytes = n * hop * wo * cout * out_itemsize if has_residual else 0

    out_buf = BufferAccess(
        name="output",
        block=(1, bh, wo, vn),
        dims=(n, hop, wo, cout),
        valid=(n, hop, wo, cout),
        index_map=conv_out_index_map(hb),
        policy="distinct",
        itemsize=out_itemsize,
    )
    extras: list[BufferAccess] = []
    if has_scale:
        extras.append(BufferAccess(
            name="scale", block=(1, vn), dims=(nb, vn), valid=(nb, vn),
            index_map=conv_bias_index_map(), policy="excluded",
            itemsize=out_itemsize,
        ))
    if has_bias:
        extras.append(BufferAccess(
            name="bias", block=(1, vn), dims=(nb, vn), valid=(nb, vn),
            index_map=conv_bias_index_map(), policy="excluded",
            itemsize=out_itemsize,
        ))
    if has_residual:
        extras.append(dataclasses.replace(
            out_buf, name="residual", itemsize=out_itemsize))

    if depthwise:
        # per-channel tap kernels: strip j IS the channel tile, vk==1,
        # vn == the channel-tile width, idx values are bare tap ids
        kb = kh * kw
        w_buf = BufferAccess(
            name="weights", block=(1, 1, 1, vn), dims=(nb, s_steps, 1, vn),
            valid=(nb, s_steps, 1, vn), index_map=conv_weight_index_map(),
            policy="distinct", itemsize=w_itemsize,
        )
        if impl == "halo":
            rows, bwp = halo_layout_dims(
                h, w, kh=kh, kw=kw, stride=stride, dilation=dilation,
                h_out=hop)
            in_buf = BufferAccess(
                name="input", block=(1, hh, bwp, 1, vn),
                dims=(n, rows, bwp, nb, vn), valid=(n, rows, bwp, nb, vn),
                index_map=dw_halo_in_index_map(hb, stride, bh),
                policy="distinct", itemsize=itemsize, unblocked=True,
            )
            cost = dw_halo_kernel_cost(
                n=n, hop=hop, w_out=wo, kh=kh, stride=stride, bwp=bwp, bh=bh,
                nb=nb, s_steps=s_steps, vc=vn, dilation=dilation,
                in_itemsize=itemsize, w_itemsize=w_itemsize,
                out_itemsize=out_itemsize, residual_bytes=res_bytes,
            )
            kind = "dw_halo"
        else:
            planes, bw = stack_layout_dims(
                h, w, kh=kh, kw=kw, stride=stride, dilation=dilation,
                h_out=hop)
            in_buf = BufferAccess(
                name="input", block=(1, 1, bh, bw, vn),
                dims=(n, planes, hop, bw, cout),
                valid=(n, planes, hop, bw, cout),
                index_map=dw_stack_in_index_map(hb, kw, stride, dilation),
                policy="per_step", itemsize=itemsize,
            )
            cost = dw_stack_kernel_cost(
                n=n, hop=hop, w_out=wo, bw=bw, bh=bh, nb=nb, s_steps=s_steps,
                vc=vn, in_itemsize=itemsize, w_itemsize=w_itemsize,
                out_itemsize=out_itemsize, residual_bytes=res_bytes,
            )
            kind = "dw_stack"
        flops_per_step = 2 * bh * wo * vn
        grid = (nb, n * hb, s_steps)
        return KernelPlan(
            kind=kind, grid=grid, kb=kb, nb=nb, s_steps=s_steps,
            buffers=(in_buf, w_buf, out_buf, *extras), cost=cost,
            flops_per_step=flops_per_step,
        )

    cbg = cb // groups   # cin tiles reachable from one strip
    spg = nb // groups   # output strips per group
    assert nb % groups == 0, (cout, vn, groups)
    kb = kh * kw * cbg
    flops_per_step = 2 * bh * wo * vk * vn
    if impl == "halo":
        rows, bwp = halo_layout_dims(
            h, w, kh=kh, kw=kw, stride=stride, dilation=dilation, h_out=hop)
        resident = use_resident_halo(hop, groups)
        cost = halo_kernel_cost(
            n=n, hop=hop, w_out=wo, kh=kh, stride=stride, bwp=bwp, bh=bh,
            nb=nb, s_steps=s_steps, cb=cbg, vk=vk, vn=vn, dilation=dilation,
            resident=resident, in_itemsize=itemsize,
            w_itemsize=w_itemsize,
            out_itemsize=out_itemsize, residual_bytes=res_bytes,
        )
        w_buf = BufferAccess(
            name="weights", block=(1, 1, vk, vn), dims=(nb, s_steps, vk, vn),
            valid=(nb, s_steps, vk, vn),
            index_map=conv_weight_index_map(resident=resident),
            policy="distinct", itemsize=w_itemsize,
        )
        if resident:
            in_buf = BufferAccess(
                name="input", block=(1, hh, bwp, cb, vk),
                dims=(n, rows, bwp, cb, vk), valid=(n, rows, bwp, cb, vk),
                index_map=resident_in_index_map(hb, stride, bh),
                policy="distinct", itemsize=itemsize, unblocked=True,
            )
            grid = (n * hb, nb, s_steps)
            out_buf = dataclasses.replace(
                out_buf, index_map=conv_out_index_map(hb, resident=True))
            extras = [
                dataclasses.replace(
                    b,
                    index_map=(conv_bias_index_map(resident=True)
                               if b.name in ("bias", "scale")
                               else conv_out_index_map(hb, resident=True)))
                for b in extras
            ]
            kind = "resident"
        else:
            in_buf = BufferAccess(
                name="input", block=(1, hh, bwp, 1, vk),
                dims=(n, rows, bwp, cb, vk), valid=(n, rows, bwp, cb, vk),
                index_map=halo_in_index_map(hb, stride, bh, cbg, spg),
                policy="sweep_distinct", itemsize=itemsize, unblocked=True,
                sweep_axes=(0, 1),
            )
            grid = (nb, n * hb, s_steps)
            kind = "halo"
    else:
        planes, bw = stack_layout_dims(
            h, w, kh=kh, kw=kw, stride=stride, dilation=dilation, h_out=hop)
        cost = stack_kernel_cost(
            n=n, hop=hop, w_out=wo, bw=bw, bh=bh, nb=nb, s_steps=s_steps,
            vk=vk, vn=vn, in_itemsize=itemsize, w_itemsize=w_itemsize,
            out_itemsize=out_itemsize, residual_bytes=res_bytes,
        )
        w_buf = BufferAccess(
            name="weights", block=(1, 1, vk, vn), dims=(nb, s_steps, vk, vn),
            valid=(nb, s_steps, vk, vn), index_map=conv_weight_index_map(),
            policy="distinct", itemsize=w_itemsize,
        )
        in_buf = BufferAccess(
            name="input", block=(1, 1, bh, bw, vk), dims=(n, planes, hop, bw, c),
            valid=(n, planes, hop, bw, c),
            index_map=stack_in_index_map(hb, cbg, spg, kw, stride, dilation),
            policy="per_step", itemsize=itemsize,
        )
        grid = (nb, n * hb, s_steps)
        kind = "stack"
    return KernelPlan(
        kind=kind, grid=grid, kb=kb, nb=nb, s_steps=s_steps,
        buffers=(in_buf, w_buf, out_buf, *extras), cost=cost,
        flops_per_step=flops_per_step,
    )


def fc_plan(
    *,
    m: int,
    k: int,
    s_steps: int,
    vk: int,
    vn: int,
    nb: int,
    bm: int = 256,
    has_bias: bool = False,
    has_residual: bool = False,
    has_scale: bool = False,
    itemsize: int = 4,
    w_itemsize: int | None = None,
    out_itemsize: int | None = None,
) -> KernelPlan:
    """The `ops.vsmm` dispatch from static geometry: ``m`` logical rows
    padded to a ``bm`` multiple exactly as the wrapper pads (the plan's
    cost quotes the kernel's padded extents; ``valid`` records the logical
    ones `conv_layer_traffic` uses for the 1x1-conv route)."""
    assert k % vk == 0, (k, vk)
    out_itemsize = out_itemsize or itemsize
    w_itemsize = w_itemsize or itemsize
    bm = min(bm, _round_up(m, 8))
    mp = _round_up(m, bm)
    kb = k // vk
    res_bytes = mp * nb * vn * out_itemsize if has_residual else 0
    x_buf = BufferAccess(
        name="input", block=(bm, vk), dims=(mp, k), valid=(m, k),
        index_map=vsmm_x_index_map(), policy="per_step", itemsize=itemsize,
    )
    w_buf = BufferAccess(
        name="weights", block=(1, 1, vk, vn), dims=(nb, s_steps, vk, vn),
        valid=(nb, s_steps, vk, vn), index_map=vsmm_w_index_map(),
        policy="distinct", itemsize=w_itemsize,
    )
    out_buf = BufferAccess(
        name="output", block=(bm, vn), dims=(mp, nb * vn),
        valid=(m, nb * vn), index_map=vsmm_out_index_map(),
        policy="distinct", itemsize=out_itemsize,
    )
    extras: list[BufferAccess] = []
    if has_scale:
        extras.append(BufferAccess(
            name="scale", block=(1, vn), dims=(nb, vn), valid=(nb, vn),
            index_map=vsmm_bias_index_map(), policy="excluded",
            itemsize=out_itemsize,
        ))
    if has_bias:
        extras.append(BufferAccess(
            name="bias", block=(1, vn), dims=(nb, vn), valid=(nb, vn),
            index_map=vsmm_bias_index_map(), policy="excluded",
            itemsize=out_itemsize,
        ))
    if has_residual:
        extras.append(dataclasses.replace(
            out_buf, name="residual", itemsize=out_itemsize))
    cost = vsmm_kernel_cost(
        m=mp, nb=nb, s_steps=s_steps, vk=vk, vn=vn, in_itemsize=itemsize,
        w_itemsize=w_itemsize, out_itemsize=out_itemsize,
        residual_bytes=res_bytes,
    )
    return KernelPlan(
        kind="vsmm", grid=(nb, mp // bm, s_steps), kb=kb, nb=nb,
        s_steps=s_steps, buffers=(x_buf, w_buf, out_buf, *extras), cost=cost,
        flops_per_step=2 * bm * vk * vn,
    )
