"""Roofline terms for TPU v5e from the dry-run's compiled artifact.

    compute term    = device_FLOPs / peak_FLOP/s
    memory term     = device_HBM_bytes / HBM_bw
    collective term = device_wire_bytes / link_bw

(Equivalent to the assignment's global formulation — the SPMD module is the
per-device program, so device_X = global_X / chips.)  The dominant term is
the bottleneck; step time >= max(terms); roofline fraction = compute term /
max(terms) (how close the step is to pure-MXU-bound).
"""
from __future__ import annotations

import dataclasses
import json

from .hlo import HloCost

__all__ = ["HW", "V5E", "HOST_CPU", "RooflineReport", "report"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float      # FLOP/s per chip (bf16)
    hbm_bw: float          # bytes/s per chip
    link_bw: float         # bytes/s per ICI link
    hbm_bytes: float       # capacity per chip


V5E = HW(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
         hbm_bytes=16e9)

# Nominal CI-runner class host: one AVX2 core's f32 FMA peak and a
# conservative DRAM stream bandwidth (the denominator the calibration's
# byte term uses — core.calibration.CPU_HBM_GBPS is this figure in GB/s).
HOST_CPU = HW(name="host-cpu", peak_flops=1e11, hbm_bw=20e9, link_bw=0.0,
              hbm_bytes=16e9)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    device_flops: float
    device_bytes: float
    device_coll_bytes: float
    model_flops: float            # 6*N*D useful-work reference (global)
    arg_bytes: float              # per-device argument residency
    temp_bytes: float
    coll_by_kind: dict
    notes: str = ""

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute term / max term — 1.0 means pure compute-bound."""
        return self.compute_s / max(self.step_time_s, 1e-30)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (remat/redundancy/attention waste)."""
        global_flops = self.device_flops * self.chips
        return self.model_flops / max(global_flops, 1e-30)

    @property
    def mfu(self) -> float:
        """model FLOPs / (chips * peak * step_time) — the MFU the roofline
        model predicts if the step ran exactly at its dominant bound."""
        return self.model_flops / (self.chips * 197e12 * max(self.step_time_s, 1e-30))

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_ms": self.compute_s * 1e3,
            "memory_ms": self.memory_s * 1e3,
            "collective_ms": self.collective_s * 1e3,
            "dominant": self.dominant,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": self.useful_flops_ratio,
            "predicted_mfu": self.mfu,
            "device_flops": self.device_flops,
            "device_bytes": self.device_bytes,
            "device_coll_bytes": self.device_coll_bytes,
            "model_flops": self.model_flops,
            "arg_gb": self.arg_bytes / 1e9,
            "temp_gb": self.temp_bytes / 1e9,
            "coll_by_kind": {k: v for k, v in sorted(
                self.coll_by_kind.items(), key=lambda kv: -kv[1])},
            "notes": self.notes,
        }

    def summary(self) -> str:
        r = self.row()
        return (
            f"{self.arch} x {self.shape} @ {self.mesh} ({self.chips} chips)\n"
            f"  compute {r['compute_ms']:9.3f} ms | memory {r['memory_ms']:9.3f} ms"
            f" | collective {r['collective_ms']:9.3f} ms  -> {self.dominant}-bound\n"
            f"  roofline fraction {self.roofline_fraction:5.1%}"
            f" | useful-FLOPs ratio {self.useful_flops_ratio:5.2f}"
            f" | predicted MFU {self.mfu:5.1%}\n"
            f"  per-device: {self.device_flops/1e12:.2f} TFLOP,"
            f" {self.device_bytes/1e9:.2f} GB HBM, {self.device_coll_bytes/1e9:.3f} GB wire,"
            f" args {self.arg_bytes/1e9:.2f} GB, temps {self.temp_bytes/1e9:.2f} GB"
        )


def report(*, arch: str, shape: str, mesh_name: str, chips: int, cost: HloCost,
           model_flops: float, mem_stats=None, hw: HW = V5E,
           notes: str = "") -> RooflineReport:
    arg_b = getattr(mem_stats, "argument_size_in_bytes", 0) if mem_stats else 0
    tmp_b = getattr(mem_stats, "temp_size_in_bytes", 0) if mem_stats else 0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        compute_s=cost.flops / hw.peak_flops,
        memory_s=cost.bytes / hw.hbm_bw,
        collective_s=cost.coll_bytes / hw.link_bw,
        device_flops=cost.flops,
        device_bytes=cost.bytes,
        device_coll_bytes=cost.coll_bytes,
        model_flops=model_flops,
        arg_bytes=arg_b,
        temp_bytes=tmp_b,
        coll_by_kind=cost.coll_by_kind,
        notes=notes,
    )


def save_rows(path: str, rows: list[dict]):
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
