"""Static analyzer for optimized (post-SPMD) HLO text.

XLA's `compiled.cost_analysis()` reports per-device FLOPs/bytes but counts
each while-loop *body once* — useless for scan-over-layers programs where
almost all compute lives inside loops.  This parser rebuilds the cost model
from `compiled.as_text()`:

  * per-computation recursive costing, while bodies multiplied by their trip
    count (extracted from the loop-condition's compare-against-constant),
  * FLOPs from dot/convolution shapes (2 * result * contraction) plus
    fused floating-point multiplies counted as multiply-add pairs (the
    depthwise path's elementwise MACs),
  * HBM bytes with fusion-boundary semantics (a fusion touches its params +
    result; internals stay on-chip) — the roofline-correct convention,
  * collective wire bytes per device with ring-algorithm factors and
    replica-group sizes parsed per op.

All numbers are PER DEVICE (the SPMD module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["HloCost", "parse_hlo", "analyze", "analyze_compiled",
           "collective_report"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce-start", "all-gather-start", "all-reduce", "all-gather",
    "reduce-scatter", "all-to-all", "collective-permute-start",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0            # HBM traffic (fusion-boundary convention)
    coll_bytes: float = 0.0       # wire bytes over the interconnect
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_ops: list = dataclasses.field(default_factory=list)

    def __add__(self, o):
        kinds = dict(self.coll_by_kind)
        for k, v in o.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return HloCost(self.flops + o.flops, self.bytes + o.bytes,
                       self.coll_bytes + o.coll_bytes, kinds,
                       self.coll_ops + o.coll_ops)

    def scale(self, k: float):
        return HloCost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                       {kk: v * k for kk, v in self.coll_by_kind.items()},
                       [(n, b * k, s) for (n, b, s) in self.coll_ops])


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> float:
    """'f32[128,512]{1,0}' or '(f32[2], s32[])' -> total bytes."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    if not _SHAPE_RE.search(type_str):
        # scalar like 'f32[]' matched above with empty dims; 's32[]' too.
        pass
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


_FLOAT_DTYPES = {"f16", "bf16", "f32", "f64", "f8e4m3fn", "f8e5m2"}


def _is_float(type_str: str) -> bool:
    m = _SHAPE_RE.search(type_str)
    return bool(m) and m.group(1) in _FLOAT_DTYPES


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # everything after the op name

    @property
    def result_bytes(self) -> float:
        return _shape_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False

    def by_name(self):
        return {i.name: i for i in self.instrs}


_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^()]*\))*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$"
)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            # computation headers sit at column 0 and end with '{'
            if (line and not line[0].isspace() and line.endswith("{")
                    and not line.startswith("HloModule")):
                m = _COMP_NAME.match(line)
                if m:
                    cur = Computation(m.group(1), [],
                                      is_entry=line.startswith("ENTRY"))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


# ---------------------------------------------------------------------------
# costing
# ---------------------------------------------------------------------------

_CALLEE = re.compile(r"(?:body|condition|to_apply|branch_computations|called_computations|calls)="
                     r"[{]?%?([\w\.\-_,% ]+)[}]?")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_HINT = re.compile(r"known_trip_count\D*(\d+)")


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 1


def _operand_names(rest: str):
    # operands are the leading %refs inside the parens (up to matching
    # close); commas inside shape brackets or layout braces
    # ('f32[64,128]{1,0}') don't split
    depth, nest, out, cur = 1, 0, [], ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                out.append(cur)
                break
        elif ch in "{[":
            nest += 1
        elif ch in "}]":
            nest -= 1
        if depth >= 1 and ch not in "()":
            cur += ch
        if ch == "," and depth == 1 and nest == 0:
            out.append(cur[:-1])
            cur = ""
    names = []
    for tok in out:
        tok = tok.strip()
        if tok.startswith("%"):
            names.append(tok[1:])
        else:
            m = re.match(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s+%?([\w\.\-_]+)", tok)
            if m:
                names.append(m.group(1))
    return names


def _dot_flops(instr: Instr, table: dict) -> float:
    result = _shape_dims(instr.type_str)
    m = _CONTRACT.search(instr.rest)
    contract = 1
    ops = _operand_names(instr.rest)
    if m and ops and ops[0] in table:
        lhs_dims = _shape_dims(table[ops[0]].type_str)
        idx = [int(i) for i in m.group(1).split(",") if i != ""]
        for i in idx:
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * math.prod(result or [1]) * contract


def _conv_flops(instr: Instr, table: dict) -> float:
    result = _shape_dims(instr.type_str)
    ops = _operand_names(instr.rest)
    kernel = _shape_dims(table[ops[1]].type_str) if len(ops) > 1 and ops[1] in table else []
    fgc = 1
    m = re.search(r"feature_group_count=(\d+)", instr.rest)
    if m:
        fgc = int(m.group(1))
    # kernel = spatial... x in_ch/fgc x out_ch (HWIO-ish); flops =
    # 2 * result * (kernel elements per output feature)
    per_out = math.prod(kernel[:-1] or [1])
    return 2.0 * math.prod(result or [1]) * per_out / max(fgc, 1) * (
        fgc if False else 1
    ) * 1.0


def _while_trips(cond: Computation) -> int:
    # find the constant feeding the ROOT compare
    consts = {}
    for i in cond.instrs:
        if i.op == "constant":
            m = re.search(r"constant\((-?\d+)", "constant(" + i.rest)
            if m:
                consts[i.name] = int(m.group(1))
    for i in cond.instrs:
        if i.op == "compare":
            for opn in _operand_names(i.rest):
                if opn in consts and consts[opn] > 0:
                    return consts[opn]
    pos = [v for v in consts.values() if v > 0]
    return max(pos) if pos else 1


_SKIP_MEM = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}

_CTRL = {"while", "conditional", "call", "fusion", "custom-call",
         "async-start", "async-done", "reduce", "sort", "scatter", "map",
         "all-reduce", "reduce-scatter", "select-and-scatter", "reduce-window"}


def _cost_of(comp: Computation, comps: dict, memo: dict,
             fusion_ctx: bool = False) -> HloCost:
    if comp.name in memo:
        return memo[comp.name]
    table = comp.by_name()
    total = HloCost()
    for ins in comp.instrs:
        op = ins.op
        if op == "while":
            callees = {}
            for key in ("condition", "body"):
                m = re.search(key + r"=%?([\w\.\-_]+)", ins.rest)
                if m:
                    callees[key] = m.group(1)
            trips = 1
            mt = _TRIP_HINT.search(ins.rest)
            if mt:
                trips = int(mt.group(1))
            elif callees.get("condition") in comps:
                trips = _while_trips(comps[callees["condition"]])
            if callees.get("body") in comps:
                total = total + _cost_of(comps[callees["body"]], comps, memo).scale(trips)
        elif op in ("call", "async-start"):
            m = re.search(r"(?:to_apply|calls)=%?([\w\.\-_]+)", ins.rest)
            if m and m.group(1) in comps:
                total = total + _cost_of(comps[m.group(1)], comps, memo)
        elif op == "conditional":
            m = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
            branches = []
            if m:
                for b in m.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b in comps:
                        branches.append(_cost_of(comps[b], comps, memo))
            if branches:  # charge the max-cost branch
                best = max(branches, key=lambda c: c.flops + c.bytes)
                total = total + best
        elif op == "fusion":
            m = re.search(r"calls=%?([\w\.\-_]+)", ins.rest)
            inner = HloCost()
            callee = comps.get(m.group(1)) if m else None
            if callee is not None:
                inner = _cost_of(callee, comps, memo, fusion_ctx=True)
            # fusion boundary: params + result cross HBM; flops from inside.
            # Slice-aware: a param consumed only via dynamic-slice/gather
            # reads slice-sized bytes, not the whole (e.g. stacked-scan-
            # weights) buffer; a root dynamic-update-slice writes only the
            # update (XLA aliases the buffer in place).
            opbytes = 0.0
            names = _operand_names(ins.rest)
            peff = _fusion_param_bytes(callee) if callee is not None else {}
            for idx, o in enumerate(names):
                if o not in table:
                    continue
                full = table[o].result_bytes
                opbytes += min(full, peff.get(idx, full))
            result_b = ins.result_bytes
            if callee is not None:
                rb = _fusion_root_write_bytes(callee)
                if rb is not None:
                    result_b = min(result_b, rb)
            total = total + HloCost(
                flops=inner.flops,
                bytes=result_b + opbytes,
                coll_bytes=inner.coll_bytes,
                coll_by_kind=inner.coll_by_kind,
                coll_ops=inner.coll_ops,
            )
        elif op.startswith(tuple(_COLLECTIVES)) or op in _COLLECTIVES:
            n = _group_size(ins.rest)
            size = ins.result_bytes
            if op.startswith("all-reduce"):
                wire = 2.0 * size * (n - 1) / max(n, 1)
            elif op.startswith("all-gather"):
                wire = size * (n - 1) / max(n, 1)
            elif op.startswith("reduce-scatter"):
                opbytes = sum(
                    table[o].result_bytes for o in _operand_names(ins.rest) if o in table
                ) or size * n
                wire = opbytes * (n - 1) / max(n, 1)
            elif op.startswith("all-to-all") or op.startswith("ragged-all-to-all"):
                wire = size * (n - 1) / max(n, 1)
            else:  # permute / broadcast
                wire = size
            kind = op.replace("-start", "")
            total = total + HloCost(
                bytes=2.0 * size,
                coll_bytes=wire,
                coll_by_kind={kind: wire},
                coll_ops=[(kind, wire, ins.type_str[:60])],
            )
        elif op == "dot":
            opbytes = sum(
                table[o].result_bytes for o in _operand_names(ins.rest) if o in table
            )
            total = total + HloCost(flops=_dot_flops(ins, table),
                                    bytes=ins.result_bytes + opbytes)
        elif op == "convolution":
            opbytes = sum(
                table[o].result_bytes for o in _operand_names(ins.rest) if o in table
            )
            total = total + HloCost(flops=_conv_flops(ins, table),
                                    bytes=ins.result_bytes + opbytes)
        elif op in ("dynamic-slice", "gather"):
            if not fusion_ctx:
                total = total + HloCost(bytes=2.0 * ins.result_bytes)
        elif op == "dynamic-update-slice":
            if not fusion_ctx:
                ops_ = _operand_names(ins.rest)
                upd = (table[ops_[1]].result_bytes
                       if len(ops_) > 1 and ops_[1] in table
                       else ins.result_bytes)
                total = total + HloCost(bytes=2.0 * upd)
        elif op == "scatter":
            if not fusion_ctx:
                ops_ = _operand_names(ins.rest)
                upd = (table[ops_[2]].result_bytes
                       if len(ops_) > 2 and ops_[2] in table
                       else ins.result_bytes)
                total = total + HloCost(bytes=2.0 * upd)
        elif op in _SKIP_MEM:
            continue
        else:
            if fusion_ctx:
                # inside a fusion, a floating-point multiply is the only
                # elementwise op that counts: one fused multiply-add pair
                # (2 FLOPs per result element) — the depthwise structural
                # path's MACs lower to exactly these, never to dots.  Adds,
                # maxima, selects etc. stay free so epilogue fusions (bias +
                # ReLU) don't perturb the matmul-path FLOP anchor.
                if op == "multiply" and _is_float(ins.type_str):
                    total = total + HloCost(
                        flops=2.0 * math.prod(_shape_dims(ins.type_str)
                                              or [1]))
                continue
            # generic elementwise-ish op outside a fusion: touches
            # operands+result
            opbytes = sum(
                table[o].result_bytes for o in _operand_names(ins.rest) if o in table
            )
            total = total + HloCost(bytes=ins.result_bytes + opbytes)
    memo[comp.name] = total
    return total


def _fusion_param_bytes(comp: Computation) -> dict[int, float]:
    """Param index -> effective read bytes (slice-aware)."""
    table = comp.by_name()
    out = {}
    params = {}
    for ins in comp.instrs:
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)", "parameter(" + ins.rest)
            if m:
                params[ins.name] = int(m.group(1))
    root = comp.instrs[-1] if comp.instrs else None
    for pname, pidx in params.items():
        uses = [i for i in comp.instrs if pname in _operand_names(i.rest)]
        if not uses:
            out[pidx] = 0.0
        elif all(u.op in ("dynamic-slice", "gather") for u in uses):
            out[pidx] = sum(u.result_bytes for u in uses)
        elif (root is not None and root.op == "dynamic-update-slice"
              and len(uses) == 1 and uses[0] is root
              and _operand_names(root.rest)[:1] == [pname]):
            out[pidx] = 0.0  # in-place DUS target: aliased, not read
    return out


def _fusion_root_write_bytes(comp: Computation) -> float | None:
    """If the fusion root is a dynamic-update-slice, only the update crosses
    HBM (XLA aliases the buffer)."""
    if not comp.instrs:
        return None
    root = comp.instrs[-1]
    if root.op == "dynamic-update-slice":
        table = comp.by_name()
        ops_ = _operand_names(root.rest)
        if len(ops_) > 1 and ops_[1] in table:
            return table[ops_[1]].result_bytes
    return None


def analyze(hlo_text: str, entry: str | None = None) -> HloCost:
    comps = parse_hlo(hlo_text)
    if not comps:
        return HloCost()
    if entry is None:
        marked = [n for n, c in comps.items() if c.is_entry]
        if marked:
            entry = marked[0]
        else:
            called = set()
            for c in comps.values():
                for ins in c.instrs:
                    pat = r"(?:condition|body|to_apply|calls)=%?([\w\.\-_]+)"
                    for m in re.finditer(pat, ins.rest):
                        called.add(m.group(1))
            roots = [n for n in comps if n not in called]
            entry = next((n for n in roots if "main" in n),
                         roots[-1] if roots else list(comps)[-1])
    return _cost_of(comps[entry], comps, {})


def analyze_compiled(compiled) -> HloCost:
    """`analyze` over a jax `Compiled` object's optimized HLO text.

    FLOPs come from dot/convolution shapes plus fused floating-point
    multiplies (each counted as a multiply-add pair): a program whose math
    lowers to fused elementwise MACs — the depthwise conv path — reports
    its structural FLOPs too, so `flops_model_ratio` holds on every layer.
    """
    return analyze(compiled.as_text())


def collective_report(cost: HloCost, top: int = 12) -> str:
    lines = [f"collective wire bytes/device: {cost.coll_bytes/1e9:.3f} GB"]
    for k, v in sorted(cost.coll_by_kind.items(), key=lambda kv: -kv[1]):
        lines.append(f"  {k:24s} {v/1e9:9.3f} GB")
    biggest = sorted(cost.coll_ops, key=lambda t: -t[1])[:top]
    for kind, b, shape in biggest:
        lines.append(f"    {kind:22s} {b/1e6:10.1f} MB  {shape}")
    return "\n".join(lines)
