"""Analysis utilities: post-SPMD HLO cost analyzer + v5e roofline model."""
from . import hlo, roofline
